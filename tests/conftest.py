"""Shared test configuration.

The property tests use ``hypothesis`` when it is installed.  Some CPU test
environments (including the pinned CI image) don't ship it, and a hard
``from hypothesis import ...`` at module scope used to kill collection of
six test modules.  Two layers of defense:

* every test module guards the import with ``pytest.importorskip``;
* this conftest installs a tiny *deterministic* fallback into
  ``sys.modules`` first, so the property tests still run (over a fixed,
  seeded sample of each strategy) instead of skipping wholesale.

The fallback implements only what the suite uses — ``given``, ``settings``
and the ``integers`` / ``lists`` / ``sampled_from`` strategies — drawing
examples from ``random.Random(0)`` so failures reproduce exactly.
"""
from __future__ import annotations

import random
import sys
import types

_FALLBACK_EXAMPLES = 10        # per test; real hypothesis uses its own count


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


def _integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))])


def _lists(elem, min_size=0, max_size=10):
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elem.example(rng) for _ in range(n)]
    return _Strategy(draw)


def _given(*strategies):
    def deco(f):
        def wrapper(*args, **kwargs):
            rng = random.Random(0)
            n = getattr(wrapper, "_max_examples", _FALLBACK_EXAMPLES)
            for _ in range(min(n, _FALLBACK_EXAMPLES)):
                drawn = [s.example(rng) for s in strategies]
                f(*args, *drawn, **kwargs)
        wrapper.__name__ = f.__name__
        wrapper.__doc__ = f.__doc__
        wrapper.hypothesis_fallback = True
        return wrapper
    return deco


def _settings(**kwargs):
    def deco(f):
        if kwargs.get("max_examples"):
            f._max_examples = kwargs["max_examples"]
        return f
    return deco


def _install_fallback():
    mod = types.ModuleType("hypothesis")
    mod.__doc__ = "deterministic mini-hypothesis fallback (tests/conftest.py)"
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = _integers
    strategies.lists = _lists
    strategies.sampled_from = _sampled_from
    mod.given = _given
    mod.settings = _settings
    mod.strategies = strategies
    mod.HealthCheck = types.SimpleNamespace(all=staticmethod(lambda: []))
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


try:                                            # pragma: no cover
    import hypothesis  # noqa: F401
except ImportError:
    _install_fallback()
